"""Multi-DNN streaming serving engine (paper §2.2 / §4.4, Fig 6).

Models are registered with the engine; requests queue per model and are
*interleaved* round-robin across models (per-model FIFO preserved). All
executors share one budgeted ``WeightCache`` — the device-memory pool —
and the engine plans every registered model jointly via
``plan_multi_model`` so each model's execution peak fits the pool budget.

While request *k* executes, the engine overlaps request *k+1*'s model:

  * plan-aware protection — cached entries the next model's OverlapPlan
    schedules earliest are PINNED, so the current model's streaming
    pressure recycles its own bytes instead of evicting exactly what the
    schedule needs next (a shared LRU pool thrashes on sequential weight
    scans without this);
  * prefetch — within the headroom ``budget - peak(current)``, the next
    model's preload weights and earliest-scheduled chunks are loaded into
    the pool by a background thread (the cross-model analogue of the
    paper's intra-model compute/load overlap).

Two policies:
  * "stream"  — FlashMem: per-model OverlapPlans, chunks checked in/out of
    the shared pool, freed at last use.
  * "preload" — each request loads its full model then runs (MNN-style);
    with a shared pool it still gets cross-request residency hits.

Without ``budget_bytes`` the engine runs cache-less (seed behaviour):
per-request streaming against ``m_peak``, no cross-model state, and
global-FIFO response order (interleaving defaults on only with a shared
pool; pass ``interleave=`` explicitly to override either way).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core.capacity import HWSpec, capacities
from repro.core.opg import OPGProblem
from repro.core.plan import MultiModelPlan, OverlapPlan, plan_multi_model
from repro.core.solver import SolverConfig, solve
from repro.core.streaming import (HostModel, PreloadExecutor, RunStats,
                                  StreamingExecutor, chunk_rows)
from repro.serving.weight_cache import WeightCache


@dataclass
class Request:
    model: str
    tokens: np.ndarray
    arrival_s: float = field(default_factory=time.perf_counter)


@dataclass
class Response:
    model: str
    latency_s: float
    init_s: float
    exec_s: float
    peak_bytes: int
    avg_bytes: float = 0.0
    cache_hits: int = 0
    cache_misses: int = 0
    cache_hit_rate: float = 0.0
    result: object = None


@dataclass
class ModelReport:
    """Per-model aggregate over a run_all batch."""
    requests: int = 0
    peak_bytes: int = 0
    avg_bytes: float = 0.0
    cache_hits: int = 0
    cache_misses: int = 0

    @property
    def cache_hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0


class ServingEngine:
    def __init__(self, *, policy: str = "stream", chunk_bytes: int = 1 << 20,
                 m_peak: int = 256 << 20, hw: Optional[HWSpec] = None,
                 disk_bw: float = 0.0,
                 solver_cfg: Optional[SolverConfig] = None,
                 budget_bytes: Optional[int] = None,
                 prefetch: bool = True,
                 interleave: Optional[bool] = None):
        assert policy in ("stream", "preload")
        self.policy = policy
        self.chunk_bytes = chunk_bytes
        self.m_peak = m_peak
        self.hw = hw or HWSpec.cpu_calibrated()
        self.disk_bw = disk_bw
        self.solver_cfg = solver_cfg
        self.budget_bytes = budget_bytes
        self.cache = WeightCache(budget_bytes) if budget_bytes else None
        self.prefetch = prefetch and self.cache is not None
        # default: interleave only with a shared pool; cache-less mode keeps
        # the seed engine's global-FIFO response order (callers pair
        # responses with submissions by index)
        self.interleave = (self.cache is not None) if interleave is None \
            else interleave
        self.models: Dict[str, HostModel] = {}
        self.plans: Dict[str, OverlapPlan] = {}
        self.multi_plan: Optional[MultiModelPlan] = None
        self.queue: List[Request] = []
        self.timeline: List[tuple] = []       # (t, resident_bytes, model)
        self.stats_log: List[RunStats] = []
        self._executors: Dict[str, object] = {}
        self._protected: Dict[str, List[tuple]] = {}
        self._planned = False

    # -- registration ------------------------------------------------------
    def register(self, name: str, model: HostModel):
        self.models[name] = model
        self._planned = False
        # re-planning replaces EVERY model's plan (the budget is shared),
        # so every cached executor is stale, not just this model's
        self._executors.clear()
        if self.policy == "stream" and self.cache is None:
            # legacy single-model planning against m_peak (no shared pool)
            g = model.graph
            caps = capacities(g, self.chunk_bytes, self.hw)
            prob = OPGProblem(g, self.chunk_bytes, self.m_peak, caps)
            sol = solve(prob, self.solver_cfg)
            self.plans[name] = OverlapPlan.from_solution(prob, sol)

    def _ensure_planned(self):
        if self._planned:
            return
        if self.policy == "stream" and self.cache is not None:
            self.multi_plan = plan_multi_model(
                {n: m.graph for n, m in self.models.items()},
                self.chunk_bytes, self.budget_bytes, hw=self.hw,
                solver_cfg=self.solver_cfg)
            self.plans = dict(self.multi_plan.plans)
        self._planned = True

    def _executor(self, name: str):
        ex = self._executors.get(name)
        if ex is None:
            if self.policy == "stream":
                ex = StreamingExecutor(self.models[name], self.plans[name],
                                       disk_bw=self.disk_bw, cache=self.cache,
                                       cache_key=name)
            else:
                ex = PreloadExecutor(self.models[name], disk_bw=self.disk_bw,
                                     cache=self.cache, cache_key=name)
            self._executors[name] = ex
        return ex

    # -- scheduling --------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _schedule(self) -> List[Request]:
        """Interleave across models round-robin, preserving each model's
        FIFO order — the multi-DNN mix the paper's Fig 6 measures."""
        if not self.interleave:
            out, self.queue = self.queue, []
            return out
        per_model: Dict[str, List[Request]] = {}
        for r in self.queue:
            per_model.setdefault(r.model, []).append(r)
        self.queue = []
        out: List[Request] = []
        while any(per_model.values()):
            for name in list(per_model):
                if per_model[name]:
                    out.append(per_model[name].pop(0))
        return out

    # -- cross-model overlap ----------------------------------------------
    def _peak_estimate(self, name: str) -> int:
        if self.multi_plan is not None and name in self.multi_plan.peaks:
            return self.multi_plan.peaks[name]
        return sum(a.nbytes for a in self.models[name].host_weights.values())

    def _protect_and_prefetch(self, name: str, limit: int,
                              stop: threading.Event):
        """Pin the next model's earliest-scheduled resident entries and
        stream its missing ones into the pool, spending at most `limit`
        bytes of pinned+prefetched residency. Runs on a background thread
        while the current model computes; `stop` is set when that model
        finishes so the thread winds down before pins are released."""
        cache, model = self.cache, self.models[name]
        pinned = self._protected.setdefault(name, [])
        used = 0

        def hold(key, nbytes_if_load=None, host=None):
            nonlocal used
            if stop.is_set():
                return False
            got = cache.pin_existing(key)
            if got is not None:
                if used + got > limit:
                    cache.release(key)
                    return False
                pinned.append(key)
                used += got
                return True
            if host is None:
                return True                       # nothing resident, no load
            if used + nbytes_if_load > limit:
                return False
            if self.disk_bw > 0:
                # simulated storage stage, interruptible: a set stop flag
                # must not leave run_all joining through a long sleep
                if stop.wait(timeout=nbytes_if_load / self.disk_bw):
                    return False
            if stop.is_set():
                return False
            arr = (jax.device_put(host[0]), float(host[1])) \
                if isinstance(host, tuple) else jax.device_put(host)
            if cache.put(key, arr, nbytes_if_load, pin=True):
                pinned.append(key)
                used += nbytes_if_load
            return True

        if self.policy == "stream":
            plan = self.plans[name]
            sizes = {w: model.host_weights[w].nbytes
                     for w in model.graph.weights}
            whole, chunks = self.multi_plan.prefetch_schedule(
                name, sizes, limit) if self.multi_plan is not None \
                else (list(plan.preload), [])
            for w in whole:
                if not hold((name, w, "w"), sizes[w], model.host_weights[w]):
                    return
            host_chunks = {}
            for t in chunks:
                if cache.contains((name, t.weight, "w")):
                    hold((name, t.weight, "w"))   # pin assembled, skip chunks
                    continue
                if t.weight not in host_chunks:
                    host_chunks[t.weight] = chunk_rows(
                        model.host_weights[t.weight], plan.chunk_bytes)
                hcs = host_chunks[t.weight]
                for ci in range(t.chunk_lo, min(t.chunk_hi, len(hcs))):
                    if not hold((name, t.weight, ci), hcs[ci].nbytes, hcs[ci]):
                        return
            # protect the remainder of what's already resident, in op order
            for w in model.graph.weights:
                if used >= limit or stop.is_set():
                    return
                hold((name, w, "w"))
        else:
            for w in model.graph.weights:
                if not hold((name, w, "w"), model.host_weights[w].nbytes,
                            model.host_weights[w]):
                    return

    def _release_protection(self, name: str):
        for key in self._protected.pop(name, []):
            self.cache.release(key)

    # -- execution ---------------------------------------------------------
    def run_all(self) -> List[Response]:
        self._ensure_planned()
        ordered = self._schedule()
        out: List[Response] = []
        t_base = time.perf_counter()
        prefetcher: Optional[threading.Thread] = None
        pf_stop: Optional[threading.Event] = None
        for i, req in enumerate(ordered):
            nxt = ordered[i + 1] if i + 1 < len(ordered) else None
            if (self.prefetch and nxt is not None
                    and nxt.model != req.model):
                if self.multi_plan is not None:
                    limit = self.multi_plan.prefetch_budget(req.model,
                                                            reserve=0.1)
                else:       # preload policy: no plan, size from model bytes
                    limit = max(0, int(0.9 * self.budget_bytes)
                                - self._peak_estimate(req.model))
                pf_stop = threading.Event()
                prefetcher = threading.Thread(
                    target=self._protect_and_prefetch,
                    args=(nxt.model, limit, pf_stop), daemon=True)
                prefetcher.start()
            t0 = time.perf_counter()
            stats = self._executor(req.model).run(req.tokens)
            dt = time.perf_counter() - t0
            if prefetcher is not None:
                # the stop flag bounds the join: the thread checks it before
                # every hold, so no pin can be appended after this returns
                # and _release_protection cannot orphan a live pin list
                pf_stop.set()
                prefetcher.join()
                prefetcher, pf_stop = None, None
            self._release_protection(req.model)
            result, stats.result = stats.result, None   # keep the log light:
            self.stats_log.append(stats)                # the tensor goes to
                                                        # the Response only
            base_t = t0 - t_base
            n = max(len(stats.residency), 1)
            for j, r in enumerate(stats.residency):
                self.timeline.append((base_t + dt * (j + 1) / n, r,
                                      req.model))
            out.append(Response(
                req.model, dt, stats.init_s, stats.exec_s, stats.peak_bytes,
                avg_bytes=stats.avg_bytes, cache_hits=stats.cache_hits,
                cache_misses=stats.cache_misses,
                cache_hit_rate=stats.cache_hit_rate, result=result))
        return out

    # -- metrics -----------------------------------------------------------
    def peak_memory(self) -> int:
        return max((r for _, r, _ in self.timeline), default=0)

    def avg_memory(self) -> float:
        vals = [r for _, r, _ in self.timeline]
        return float(np.mean(vals)) if vals else 0.0

    def cache_hit_rate(self) -> float:
        hits = sum(s.cache_hits for s in self.stats_log)
        misses = sum(s.cache_misses for s in self.stats_log)
        return hits / (hits + misses) if hits + misses else 0.0

    def model_report(self) -> Dict[str, ModelReport]:
        """Per-model peak/avg memory and cache hit rate over run history."""
        rep: Dict[str, ModelReport] = {}
        for s in self.stats_log:
            r = rep.setdefault(s.model, ModelReport())
            r.requests += 1
            r.peak_bytes = max(r.peak_bytes, s.peak_bytes)
            r.avg_bytes += (s.avg_bytes - r.avg_bytes) / r.requests
            r.cache_hits += s.cache_hits
            r.cache_misses += s.cache_misses
        return rep
