"""Front-end Router for a fleet of ``Replica``s: cache-affinity routing,
health checks, retry with backoff + jitter, and a circuit breaker.

The fleet is the paper's memory-hierarchy argument one level up: each
replica owns a budgeted ``WeightCache``, so N replicas form one big
PARTITIONED weight cache. Sending a request to a replica that holds its
model hot costs nothing extra; sending it to a cold one costs exactly the
restream bytes the single-engine scheduler already prices. Affinity
routing therefore minimizes fleet restream traffic the same way the
engine's cost-aware eviction minimizes per-device traffic:

  * consistent hash (md5 ring, virtual nodes) of the model name picks a
    stable HOME replica — successive requests for a model keep hitting
    the cache they warmed;
  * when the home is backed up past ``spill_depth``, spill to the
    least-loaded replica whose pool already holds the model hot
    (``WeightCache.model_bytes`` residency);
  * when nobody holds it hot, cold-start on the replica with the most
    free pool budget (least eviction damage).

Failures are handled the way a real front end must — with NO privileged
view of replica state. ``Router.serve`` runs a deterministic
discrete-event pump on virtual time: request arrivals, per-attempt
timeouts, retries, scheduled fault injections, and periodic health
checks are heap events; between events the pump steps whichever replica
session's ``next_time()`` is earliest. A routed attempt that produces no
response within ``timeout_s`` counts as a failure: the request is
retried on a sibling with exponential backoff + seeded jitter, and K
consecutive failures trip the replica's circuit breaker (closed → open);
after ``cooldown_s`` a half-open probe admits one request, and a success
re-closes. The ``StragglerDetector`` (ft/resilience.py) watches
per-batch latencies from each replica's feed and trips the breaker of a
replica that is alive-but-slow — the failure mode timeouts alone catch
only after eating deadlines.

Exactly-once responses: every accepted request yields exactly ONE
terminal ``Response`` — served ("ok"), refused by a replica's admission
controller ("rejected"), or abandoned after exhausting retries
("failed"). A timed-out attempt may still complete on its original
replica after the retry was dispatched (at-least-once execution is
unavoidable without distributed consensus); the pump resolves whichever
terminal outcome lands first and suppresses later duplicates
(``dup_suppressed``).
"""
from __future__ import annotations

import bisect
import hashlib
import heapq
import itertools
import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.ft.resilience import StragglerDetector
from repro.serving.replica import FaultPlan, Replica
from repro.serving.reports import FleetReport, ReplicaHealth
from repro.serving.response_table import ResponseTable
from repro.serving.types import (Request, Response, RingLog, SLOConfig,
                                 _judged_missed, deadline_miss_rate,
                                 rejection_rate, response_columns,
                                 status_counts)

ROUTING_POLICIES = ("affinity", "round_robin")


@dataclass
class RetryPolicy:
    """Exponential backoff with seeded jitter. ``max_attempts`` counts
    every dispatch (first try included); ``delay(k)`` is the wait after
    the k-th failed attempt (k >= 1)."""
    max_attempts: int = 4
    base_s: float = 0.05
    factor: float = 2.0
    cap_s: float = 0.5
    jitter_frac: float = 0.25

    def delay(self, attempt: int, rng: np.random.Generator) -> float:
        d = min(self.cap_s, self.base_s * self.factor ** max(0, attempt - 1))
        return d * (1.0 + self.jitter_frac * float(rng.random()))


class CircuitBreaker:
    """closed → open after ``failure_threshold`` consecutive failures;
    after ``cooldown_s`` the next route becomes the half-open probe; a
    probe success re-closes, a probe failure re-opens. ``trip`` forces
    open from any state (the straggler detector's path)."""

    def __init__(self, rid: int, *, failure_threshold: int = 3,
                 cooldown_s: float = 0.25):
        self.rid = rid
        self.failure_threshold = failure_threshold
        self.cooldown_s = cooldown_s
        self.state = "closed"
        self.failures = 0
        self.opened_at = -math.inf
        self.probe_inflight = 0
        self.transitions: List[Tuple[float, str, str, str]] = []

    def _move(self, now: float, state: str, why: str):
        if state != self.state:
            self.transitions.append((now, self.state, state, why))
            self.state = state

    def available(self, now: float) -> bool:
        """May the router send this replica a request at ``now``?"""
        if self.state == "closed":
            return True
        if self.state == "half_open":
            return self.probe_inflight == 0
        return now >= self.opened_at + self.cooldown_s   # open → probe ok

    def on_route(self, now: float):
        """A request was just routed here; open→half_open on the probe."""
        if self.state == "open":
            self._move(now, "half_open", "probe")
            self.probe_inflight = 0
        if self.state == "half_open":
            self.probe_inflight += 1

    def on_success(self, now: float):
        self.failures = 0
        if self.state == "half_open":
            self.probe_inflight = 0
            self._move(now, "closed", "probe_ok")

    def on_failure(self, now: float):
        self.failures += 1
        if self.state == "half_open":
            self.probe_inflight = 0
            self.opened_at = now
            self._move(now, "open", "probe_failed")
        elif self.state == "closed" \
                and self.failures >= self.failure_threshold:
            self.opened_at = now
            self._move(now, "open",
                       f"{self.failures}_consecutive_failures")

    def trip(self, now: float, why: str = "straggler"):
        """Force open (health-check path); cooldown restarts at ``now``."""
        self.opened_at = now
        self.probe_inflight = 0
        self.failures = max(self.failures, self.failure_threshold)
        self._move(now, "open", why)


class HashRing:
    """Consistent hash ring over replica ids (md5, virtual nodes) — the
    model→home mapping is stable across runs and processes (``hash()`` is
    salted per process; md5 is not) and moves only ~1/N of models when a
    replica joins or leaves."""

    def __init__(self, rids: Sequence[int], vnodes: int = 64):
        points = sorted((self._h(f"r{rid}#v{v}"), rid)
                        for rid in rids for v in range(vnodes))
        self._hashes = [h for h, _ in points]
        self._rids = [r for _, r in points]

    @staticmethod
    def _h(key: str) -> int:
        return int(hashlib.md5(key.encode()).hexdigest()[:8], 16)

    def lookup(self, model: str) -> int:
        i = bisect.bisect_left(self._hashes, self._h(model))
        return self._rids[i % len(self._rids)]


@dataclass
class _Tracked:
    """Router-side state of one not-yet-terminal request."""
    request: Request                 # original (caller timeline)
    deadline_s: Optional[float]      # absolute, fixed at first dispatch
    attempts: int = 0                # dispatches so far
    rid: Optional[int] = None        # replica of the live attempt
    tried: Set[int] = field(default_factory=set)


class Router:
    """Cache-affinity front end over N started ``Replica``s.

    ``serve(trace)`` replays a request trace through the fleet on virtual
    time and returns exactly one terminal ``Response`` per request (in
    arrival order). All decision state is observable afterwards:
    ``route_log`` (every dispatch), ``breakers[rid].transitions``,
    ``health_log`` (straggler trips), ``fault_log`` (injected events),
    ``retries`` / ``failed`` / ``dup_suppressed`` counters.
    """

    def __init__(self, replicas: Sequence[Replica], *,
                 routing: str = "affinity",
                 retry: Optional[RetryPolicy] = None,
                 timeout_s: float = 0.5,
                 spill_depth: int = 4,
                 failure_threshold: int = 3,
                 cooldown_s: float = 0.25,
                 health_interval_s: float = 0.1,
                 straggler: Optional[StragglerDetector] = None,
                 seed: int = 0,
                 log_cap: int = 10000):
        if routing not in ROUTING_POLICIES:
            raise ValueError(f"unknown routing {routing!r}; "
                             f"expected one of {ROUTING_POLICIES}")
        self.replicas = list(replicas)
        self.by_rid = {r.rid: r for r in self.replicas}
        self.routing = routing
        self.retry = retry or RetryPolicy()
        self.timeout_s = timeout_s
        self.spill_depth = spill_depth
        self.health_interval_s = health_interval_s
        self.breakers = {r.rid: CircuitBreaker(
            r.rid, failure_threshold=failure_threshold,
            cooldown_s=cooldown_s) for r in self.replicas}
        self.straggler = straggler or StragglerDetector(
            window=16, z_thresh=3.0, patience=2)
        self._rng = np.random.default_rng(seed)
        self._rr = 0
        # observability — ring-buffered (PR 8): dispatches are O(events)
        # over a trace-scale replay; `.total` keeps lifetime counts exact
        self.route_log = RingLog(log_cap)   # (t, req_id, model, rid, why, k)
        self.health_log = RingLog(log_cap)  # (t, event, rid)
        self.fault_log = RingLog(log_cap)   # (t, kind, rid)
        self.retries = 0
        self.failed = 0
        self.dup_suppressed = 0

    # -- replica choice ----------------------------------------------------
    def _candidates(self, now: float,
                    exclude: Set[int]) -> List[Replica]:
        cands = [r for r in self.replicas
                 if self.breakers[r.rid].available(now)
                 and r.rid not in exclude]
        if not cands and exclude:
            # every untried replica is breaker-blocked: allow retrying a
            # previously-failed one rather than dropping the request
            cands = [r for r in self.replicas
                     if self.breakers[r.rid].available(now)]
        return cands

    def _pick(self, model: str, now: float,
              exclude: Set[int]) -> Tuple[Optional[Replica], str]:
        cands = self._candidates(now, exclude)
        if not cands:
            return None, "none"
        if self.routing == "round_robin":
            n = len(self.replicas)
            for i in range(n):
                r = self.replicas[(self._rr + i) % n]
                if r in cands:
                    self._rr = (self._rr + i + 1) % n
                    return r, "rr"
            return None, "none"
        ring = getattr(self, "_ring", None)
        if ring is None:        # serve() builds it once; direct calls here
            self._ring = ring = HashRing([r.rid for r in self.replicas])
        home = next((r for r in cands if r.rid == ring.lookup(model)), None)
        if home is not None and home.load() <= self.spill_depth:
            return home, "home"
        hot = [r for r in cands if r.hot_bytes(model) > 0]
        if hot:
            return min(hot, key=lambda r: (r.load(), r.rid)), "hot"
        if home is not None:
            # overloaded home, nobody else hot: queueing behind the warm
            # cache still beats restreaming the model somewhere cold
            return home, "home_backlogged"
        return min(cands,
                   key=lambda r: (-r.free_budget(), r.load(), r.rid)), "cold"

    # -- the event pump ----------------------------------------------------
    def serve(self, trace: Sequence[Request], *,
              slo: Optional[SLOConfig] = None,
              fault_plan: Optional[FaultPlan] = None):
        for r in self.replicas:
            if r.session is None:
                raise RuntimeError(f"replica {r.rid} not started — call "
                                   "replica.start(**serve_kw) first")
        # result mode follows the replicas: when every session stores a
        # columnar ResponseTable the fleet aggregate is a ResponseTable
        # too — per-replica rows are rebased onto the caller's timeline
        # column-wise, never materialized as Response objects.
        modes = {isinstance(r.session.responses, ResponseTable)
                 for r in self.replicas}
        if len(modes) > 1:
            raise ValueError(
                "mixed result modes across replicas: start every replica "
                "with the same ServeConfig.result_mode")
        columnar = modes.pop()
        out = ResponseTable() if columnar else None
        self._ring = HashRing([r.rid for r in self.replicas])
        seq = itertools.count()
        events: List[tuple] = []    # (t, seq, kind, payload)

        def push(t: float, kind: str, payload):
            heapq.heappush(events, (t, next(seq), kind, payload))

        inflight: Dict[int, _Tracked] = {}
        # object mode: req_id -> terminal Response;
        # columnar mode: req_id -> row index into `out`
        terminal: Dict[int, object] = {}
        order: List[int] = []
        drained = {r.rid: 0 for r in self.replicas}   # response cursors

        for i, req in enumerate(trace):
            rid_ = req.req_id if req.req_id is not None else i
            if rid_ in set(order):
                raise ValueError(f"duplicate req_id {rid_} in trace")
            order.append(rid_)
            push(req.arrival_s, "arrival", (rid_, req))
        if fault_plan is not None:
            for ev in fault_plan.sorted_events():
                push(ev.t_s, "fault", ev)
        push(self.health_interval_s, "health", None)

        def resolve(req_id: int, resp, now: float,
                    origin_rid: Optional[int]):
            tr = inflight.pop(req_id, None)
            if tr is None:
                self.dup_suppressed += 1
                return
            orig = tr.request
            # rebase onto the caller's timeline: latency is arrival →
            # terminal outcome, backoff/queue gaps included
            finish = resp.arrival_s + resp.latency_s
            latency = max(0.0, finish - orig.arrival_s)
            queue = resp.queue_s + max(0.0, resp.arrival_s - orig.arrival_s)
            if columnar:
                # `resp` is a row view over the replica's table; append the
                # rebased row to the fleet table and remember its index
                terminal[req_id] = len(out)
                out.append(
                    resp.model, latency_s=latency, init_s=resp.init_s,
                    exec_s=resp.exec_s, peak_bytes=resp.peak_bytes,
                    avg_bytes=resp.avg_bytes, cache_hits=resp.cache_hits,
                    cache_misses=resp.cache_misses,
                    cache_hit_rate=resp.cache_hit_rate,
                    arrival_s=orig.arrival_s, queue_s=queue,
                    batch_size=resp.batch_size, status=resp.status,
                    deadline_s=tr.deadline_s, priority=orig.priority,
                    req_id=req_id, kv_bytes=resp.kv_bytes,
                    predicted_s=resp.predicted_s, charged_s=resp.charged_s)
            else:
                terminal[req_id] = replace(
                    resp, req_id=req_id, arrival_s=orig.arrival_s,
                    latency_s=latency, queue_s=queue,
                    deadline_s=tr.deadline_s, priority=orig.priority)
            if origin_rid is not None:
                self.breakers[origin_rid].on_success(now)

        def drain(rep: Replica, now: float):
            resps = rep.session.responses
            while drained[rep.rid] < len(resps):
                resp = resps[drained[rep.rid]]
                drained[rep.rid] += 1
                resolve(resp.req_id, resp, now, rep.rid)

        def give_up(req_id: int, now: float):
            tr = inflight.pop(req_id, None)
            if tr is None:
                return
            orig = tr.request
            self.failed += 1
            if columnar:
                terminal[req_id] = len(out)
                out.append(orig.model,
                           latency_s=max(0.0, now - orig.arrival_s),
                           status="failed", arrival_s=orig.arrival_s,
                           deadline_s=tr.deadline_s, priority=orig.priority,
                           req_id=req_id)
            else:
                terminal[req_id] = Response(
                    orig.model, max(0.0, now - orig.arrival_s), 0.0, 0.0, 0,
                    status="failed", arrival_s=orig.arrival_s,
                    deadline_s=tr.deadline_s, priority=orig.priority,
                    req_id=req_id)

        def dispatch(req_id: int, now: float):
            tr = inflight.get(req_id)
            if tr is None:
                return
            if tr.attempts >= self.retry.max_attempts:
                give_up(req_id, now)
                return
            rep, why = self._pick(tr.request.model, now, tr.tried)
            tr.attempts += 1
            if rep is None:
                # nobody routable: burn the attempt and back off — the
                # fleet may recover (half-open cooldowns) before the next
                push(now + self.retry.delay(tr.attempts, self._rng),
                     "retry", req_id)
                return
            tr.rid = rep.rid
            tr.tried.add(rep.rid)
            self.breakers[rep.rid].on_route(now)
            self.route_log.append((now, req_id, tr.request.model, rep.rid,
                                   why, tr.attempts))
            rep.inbox.push(replace(tr.request, arrival_s=now,
                                   deadline_s=tr.deadline_s, req_id=req_id))
            push(now + self.timeout_s, "timeout", (req_id, tr.attempts))

        def on_fault(ev, now: float):
            rep = self.by_rid[ev.rid]
            self.fault_log.append((now, ev.kind, ev.rid))
            if ev.kind == "kill":
                rep.dead = True
            elif ev.kind == "wedge":
                rep.wedged = True
            elif ev.kind == "slow":
                rep.clock.slow_factor = ev.factor
            elif ev.kind == "recover":
                rep.wedged = False
                rep.clock.slow_factor = 1.0
                if not rep.dead and rep.clock.now() < now:
                    # the wedge held the replica's clock still; it wakes
                    # at the recovery time, not in the past
                    rep.clock.advance(now - rep.clock.now())

        def on_health(now: float):
            flagged = self.straggler.check()
            for rid in flagged:
                br = self.breakers[rid]
                if br.state == "closed":
                    br.trip(now, "straggler")
                    self.health_log.append((now, "straggler_trip", rid))
            if inflight or events:
                push(now + self.health_interval_s, "health", None)

        # pump: dispatch the earliest event, or step the earliest replica
        while True:
            t_ev = events[0][0] if events else math.inf
            runnable = [(r.next_time(), r.rid) for r in self.replicas]
            t_rep, rid_next = min(runnable, default=(math.inf, -1))
            if not math.isfinite(min(t_ev, t_rep)):
                break
            if not inflight and not events:
                break
            if t_ev <= t_rep:
                now, _, kind, payload = heapq.heappop(events)
                if kind == "arrival":
                    req_id, req = payload
                    d = req.deadline_s if req.deadline_s is not None else \
                        (slo.deadline_for(req) if slo is not None else None)
                    inflight[req_id] = _Tracked(request=req, deadline_s=d)
                    dispatch(req_id, now)
                elif kind == "timeout":
                    req_id, attempt = payload
                    tr = inflight.get(req_id)
                    if tr is None or tr.attempts != attempt \
                            or tr.rid is None:
                        continue            # stale: resolved or re-routed
                    self.breakers[tr.rid].on_failure(now)
                    tr.rid = None
                    self.retries += 1
                    push(now + self.retry.delay(tr.attempts, self._rng),
                         "retry", req_id)
                elif kind == "retry":
                    dispatch(payload, now)
                elif kind == "fault":
                    on_fault(payload, now)
                elif kind == "health":
                    on_health(now)
            else:
                rep = self.by_rid[rid_next]
                kind, payload = rep.step()
                if kind == "batch":
                    self.straggler.record(rep.rid, rep.batch_feed[-1][2])
                drain(rep, rep.clock.now())
        # anything still tracked when the pump stalls (should not happen:
        # every live attempt has a timeout event) fails loudly, not
        # silently — the exactly-one-terminal invariant must hold
        for req_id in list(inflight):
            give_up(req_id, max((r.clock.now() for r in self.replicas),
                                default=0.0))
        if columnar:
            # restore arrival order with one fancy-index over the table
            return out.take([terminal[i] for i in order if i in terminal])
        return [terminal[i] for i in order if i in terminal]

    # -- reporting ---------------------------------------------------------
    def report(self, responses) -> FleetReport:
        n = len(responses)
        c = response_columns(responses)
        counts = status_counts(responses)
        # bad = late + rejected + failed: requests that did NOT get a
        # timely served response — the fleet SLO number
        _, missed = _judged_missed(c)
        bad = (n - counts["ok"]) + int(np.count_nonzero(missed))
        return FleetReport(
            requests=n,
            served=counts["ok"],
            rejected=counts["rejected"],
            failed=counts["failed"],
            miss_rate=deadline_miss_rate(responses),
            rejection_rate=rejection_rate(responses),
            bad_rate=bad / n if n else 0.0,
            retries=self.retries,
            gave_up=self.failed,
            dup_suppressed=self.dup_suppressed,
            restream_bytes=sum(r.restream_bytes()
                               for r in self.replicas),
            per_replica={r.rid: ReplicaHealth(
                rid=r.rid, dead=r.dead, wedged=r.wedged,
                slow_factor=r.clock.slow_factor,
                batches=r.batch_feed.total,
                restream_bytes=r.restream_bytes(),
                breaker=self.breakers[r.rid].state,
                breaker_transitions=len(self.breakers[r.rid].transitions),
            ) for r in self.replicas})
