"""Typed serving reports (PR 10): one dataclass per report surface,
replacing the ad-hoc string-keyed dicts ``slo_report()`` /
``model_report()`` / ``Router.report()`` / ``Replica.health()`` used to
return.

Every report shares the ``ReportBase`` contract:

  * ``as_dict()`` — plain nested dict/float/int payload (JSON-ready,
    what the benchmarks serialize into ``BENCH_*.json``);
  * ``from_dict(d)`` — the inverse (round-trip tested);
  * ``report["field"]`` — mapping-style access kept for migration, so
    callers that still string-pluck keys keep working;
  * NaN-aware equality — dataclass ``==`` treating NaN == NaN, so two
    reports from bit-identical runs compare equal even when an empty
    latency cell reads NaN;
  * ``WINDOWED_FIELDS`` — the class-level label separating exact
    lifetime counters from fields derived from ring-buffered windows
    (PR 8 bounded logs): at trace scale a windowed field describes the
    most recent ``log_cap`` events, not the lifetime.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import ClassVar, Dict, Tuple


def _eq(a, b) -> bool:
    if isinstance(a, float) and isinstance(b, float):
        return a == b or (a != a and b != b)        # NaN == NaN
    if isinstance(a, dict) and isinstance(b, dict):
        return (a.keys() == b.keys()
                and all(_eq(a[k], b[k]) for k in a))
    if isinstance(a, (list, tuple)) and isinstance(b, (list, tuple)):
        return len(a) == len(b) and all(_eq(x, y) for x, y in zip(a, b))
    return a == b


class ReportBase:
    """Shared report behaviour: ``as_dict``/``from_dict`` round-trip,
    mapping-style ``report["field"]`` access, NaN-aware equality."""

    #: fields derived from ring-buffered logs — a WINDOW at trace scale,
    #: not a lifetime aggregate. Everything else is an exact counter or
    #: an exact reduction over the responses passed in.
    WINDOWED_FIELDS: ClassVar[Tuple[str, ...]] = ()

    def as_dict(self) -> dict:
        out = {}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, ReportBase):
                v = v.as_dict()
            elif isinstance(v, dict):
                v = {k: (x.as_dict() if isinstance(x, ReportBase) else x)
                     for k, x in v.items()}
            out[f.name] = v
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "ReportBase":
        kw = dict(d)
        for f in dataclasses.fields(cls):
            sub = _NESTED.get((cls.__name__, f.name))
            if sub is not None and f.name in kw:
                v = kw[f.name]
                if isinstance(v, dict) and not isinstance(v, sub):
                    kw[f.name] = {k: (sub.from_dict(x)
                                      if isinstance(x, dict) else x)
                                  for k, x in v.items()}
        return cls(**kw)

    def __getitem__(self, key: str):
        try:
            return getattr(self, key)
        except AttributeError:
            raise KeyError(key) from None

    def keys(self):
        return [f.name for f in dataclasses.fields(self)]

    def __contains__(self, key: str) -> bool:
        return any(f.name == key for f in dataclasses.fields(self))

    def __eq__(self, other) -> bool:
        if other.__class__ is not self.__class__:
            return NotImplemented
        return all(_eq(getattr(self, f.name), getattr(other, f.name))
                   for f in dataclasses.fields(self))

    __hash__ = None


@dataclass(eq=False)
class PriorityStats(ReportBase):
    """One priority class's outcome (``per_priority_stats``): exact
    counts/rates plus served-latency percentiles (NaN when the class had
    no served request)."""
    requests: int = 0
    served: int = 0
    rejected: int = 0
    miss_rate: float = 0.0
    rejection_rate: float = 0.0
    p50_s: float = float("nan")
    p99_s: float = float("nan")


@dataclass(eq=False)
class SLOReport(ReportBase):
    """``ServingEngine.slo_report``: exact reductions over the responses
    passed in, plus the engine-lifetime intervention counters.
    ``calibration`` is the learned cost model's per-model fit telemetry
    (``{}`` under the plain EWMA estimator)."""
    requests: int = 0
    served: int = 0
    miss_rate: float = 0.0
    rejection_rate: float = 0.0
    priority_miss_rate: float = 0.0
    per_priority: Dict[float, PriorityStats] = field(default_factory=dict)
    preemptions: int = 0
    deferred_joins: int = 0
    calibration: dict = field(default_factory=dict)


@dataclass(eq=False)
class ModelReport(ReportBase):
    """Per-model aggregate over a run_all/serve history. Derived from
    the ring-buffered ``stats_log`` — at trace scale this is the most
    recent window, not the lifetime (see ``WINDOWED_FIELDS``)."""
    WINDOWED_FIELDS: ClassVar[Tuple[str, ...]] = (
        "requests", "peak_bytes", "avg_bytes", "cache_hits",
        "cache_misses")
    requests: int = 0
    peak_bytes: int = 0
    avg_bytes: float = 0.0
    cache_hits: int = 0
    cache_misses: int = 0

    @property
    def cache_hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0


@dataclass(eq=False)
class ReplicaHealth(ReportBase):
    """One replica's observable state. Produced by ``Replica.health()``
    (live view: load/clock/budget filled) and embedded per-replica in
    ``FleetReport`` (breaker fields filled by the Router)."""
    rid: int = 0
    dead: bool = False
    wedged: bool = False
    slow_factor: float = 1.0
    load: int = 0
    clock_s: float = 0.0
    batches: int = 0
    free_budget: int = 0
    restream_bytes: int = 0
    breaker: str = ""
    breaker_transitions: int = 0


@dataclass(eq=False)
class FleetReport(ReportBase):
    """``Router.report``: fleet-wide outcome counters (exact) plus the
    per-replica health snapshots."""
    requests: int = 0
    served: int = 0
    rejected: int = 0
    failed: int = 0
    miss_rate: float = 0.0
    rejection_rate: float = 0.0
    bad_rate: float = 0.0
    retries: int = 0
    gave_up: int = 0
    dup_suppressed: int = 0
    restream_bytes: int = 0
    per_replica: Dict[int, ReplicaHealth] = field(default_factory=dict)


# nested-report field registry for from_dict round-trips
_NESTED = {
    ("SLOReport", "per_priority"): PriorityStats,
    ("FleetReport", "per_replica"): ReplicaHealth,
}
