"""Trace-scale workload library: seeded arrival-pattern generators that
stress the serving stack the way production traffic does, at 10^5-request
scale (benchmarks/trace_scale.py replays them).

Four families, each motivated by a real mobile/edge serving pattern:

  * ``diurnal_trace``      — sinusoidal day/night load via a thinned
    Poisson process: draw at the peak rate, accept each arrival with
    probability lambda(t)/lambda_max. Exact (no time-stepping bias) and
    seeded.
  * ``flash_crowd_trace``  — steady background traffic plus a windowed
    rate multiplier (default x20) on ONE model: the viral-moment pattern
    that floods a single entry in the weight pool.
  * ``multi_tenant_trace`` — per-tenant Poisson mixes with per-tenant
    SLOs and priorities; returns a ``req_id -> tenant`` map so per-tenant
    miss rates (and Jain fairness across tenants) can be computed from
    the engine's responses.
  * ``session_trace``      — correlated successive-model sessions (the
    paper's multi-DNN pipeline workload, e.g. ASR -> LLM -> TTS): session
    starts are Poisson, and each session walks a model chain with
    think-time gaps, so back-to-back requests hit DIFFERENT models — the
    access pattern that defeats single-model caching.

Every generator is seeded, returns arrival-sorted ``Request`` lists, and
keeps all arrivals inside ``[0, duration_s)``. Use ``stamp_req_ids``
(re-exported from serving.stream) before keying any per-request metric —
``(model, arrival_s)`` keys collapse identical arrivals.

``jain_fairness`` is the standard index ``(sum x)^2 / (n * sum x^2)``:
1.0 when every tenant gets equal service, -> 1/n when one tenant starves
the rest.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.serving.stream import (_mk_request, poisson_trace,
                                  stamp_req_ids)
from repro.serving.types import (Request, _STATUS_OK, _judged_missed,
                                 response_columns)

__all__ = [
    "TenantSpec", "diurnal_trace", "flash_crowd_trace",
    "multi_tenant_trace", "session_trace", "jain_fairness",
    "stamp_req_ids",
]


def diurnal_trace(rates: Dict[str, float], duration_s: float, *,
                  period_s: float, depth: float = 0.8,
                  phase: float = 0.0, vocab: int, seq: int,
                  seed: int = 0) -> List[Request]:
    """Sinusoidally modulated Poisson arrivals per model.

    The instantaneous rate is ``base * (1 + depth * sin(2*pi*t/period_s
    + phase))`` — a day/night cycle compressed to ``period_s``. Sampling
    is by thinning: draw a homogeneous process at the peak rate
    ``base * (1 + depth)`` and accept each point with probability
    ``lambda(t) / lambda_max``, which is exact for any inhomogeneous
    intensity bounded by ``lambda_max`` (no discretization bias, unlike
    stepping time in fixed bins). ``depth`` in [0, 1): 0 degenerates to
    ``poisson_trace``; 1 would zero the trough (and the thinning bound
    still holds, so it is allowed but leaves dead air).
    """
    if not 0.0 <= depth <= 1.0:
        raise ValueError(f"depth must be in [0, 1], got {depth}")
    rng = np.random.default_rng(seed)
    omega = 2.0 * math.pi / float(period_s)
    reqs: List[Request] = []
    for model, base in rates.items():
        if base <= 0:
            continue
        lam_max = base * (1.0 + depth)
        t = 0.0
        while True:
            t += float(rng.exponential(1.0 / lam_max))
            if t >= duration_s:
                break
            lam_t = base * (1.0 + depth * math.sin(omega * t + phase))
            if rng.random() * lam_max < lam_t:
                reqs.append(_mk_request(model, t, rng, vocab, seq))
    reqs.sort(key=lambda r: r.arrival_s)
    return reqs


def flash_crowd_trace(base_rates: Dict[str, float], duration_s: float, *,
                      crowd_model: str, start_s: float, span_s: float,
                      factor: float = 20.0, vocab: int, seq: int,
                      seed: int = 0) -> List[Request]:
    """Steady Poisson background plus a rate spike on ONE model: within
    ``[start_s, start_s + span_s)`` the crowd model's arrival rate is
    multiplied by ``factor`` (default x20 — the ISSUE's viral-moment
    scenario). Implemented as the background trace superposed with an
    extra Poisson process at ``base * (factor - 1)`` inside the window
    (superposition of Poissons is Poisson, so the in-window rate is
    exactly ``base * factor``)."""
    if factor < 1.0:
        raise ValueError(f"flash-crowd factor must be >= 1, got {factor}")
    if crowd_model not in base_rates or base_rates[crowd_model] <= 0:
        raise ValueError(f"crowd model {crowd_model!r} needs a positive "
                         f"base rate (got {base_rates.get(crowd_model)})")
    reqs = poisson_trace(base_rates, duration_s, vocab=vocab, seq=seq,
                         seed=seed)
    extra_rate = base_rates[crowd_model] * (factor - 1.0)
    rng = np.random.default_rng(seed + 101)
    end_s = min(float(duration_s), start_s + span_s)
    if extra_rate > 0:
        t = float(start_s)
        while True:
            t += float(rng.exponential(1.0 / extra_rate))
            if t >= end_s:
                break
            reqs.append(_mk_request(crowd_model, t, rng, vocab, seq))
    reqs.sort(key=lambda r: r.arrival_s)
    return reqs


@dataclass(frozen=True)
class TenantSpec:
    """One tenant's traffic contract: which models it calls, its Poisson
    arrival rate (req/s, split uniformly across its models), its SLO
    (stamped as ``deadline_s = arrival + slo_s``), and its scheduling
    priority (weighted-EDF weight)."""
    models: Tuple[str, ...]
    rate: float
    slo_s: float
    priority: float = 1.0

    def __post_init__(self):
        if not self.models:
            raise ValueError("tenant needs at least one model")
        if self.slo_s <= 0:
            raise ValueError(f"slo_s must be positive, got {self.slo_s}")


def multi_tenant_trace(tenants: Dict[str, TenantSpec], duration_s: float,
                       *, vocab: int, seq: int, seed: int = 0
                       ) -> Tuple[List[Request], Dict[int, str]]:
    """Superposed per-tenant Poisson mixes. Each tenant's arrivals pick
    uniformly among its models and carry the tenant's SLO deadline and
    priority. Returns ``(trace, tenant_of)`` where the trace is already
    ``stamp_req_ids``-stamped and ``tenant_of`` maps ``req_id`` ->
    tenant name — the only collision-safe key (two tenants can share a
    model AND an arrival time)."""
    rng = np.random.default_rng(seed)
    tagged: List[Tuple[str, Request]] = []
    for name in sorted(tenants):
        spec = tenants[name]
        if spec.rate <= 0:
            continue
        t = 0.0
        while True:
            t += float(rng.exponential(1.0 / spec.rate))
            if t >= duration_s:
                break
            model = spec.models[int(rng.integers(len(spec.models)))]
            r = _mk_request(model, t, rng, vocab, seq)
            tagged.append((name, replace(r, deadline_s=t + spec.slo_s,
                                         priority=spec.priority)))
    tagged.sort(key=lambda nr: nr[1].arrival_s)
    trace = stamp_req_ids([r for _, r in tagged])
    tenant_of = {r.req_id: name for (name, _), r in zip(tagged, trace)}
    return trace, tenant_of


def session_trace(models: Sequence[str], session_rate: float,
                  duration_s: float, *, chain_len: int = 3,
                  think_s: float = 0.5, vocab: int, seq: int,
                  seed: int = 0) -> List[Request]:
    """Correlated successive-model sessions: session STARTS are Poisson
    at ``session_rate``; each session enters the model list at a random
    offset and walks ``chain_len`` consecutive models (wrapping), with an
    exponential think-time gap (mean ``think_s``) between steps. This is
    the paper's multi-DNN pipeline pattern — consecutive requests from
    one user hit DIFFERENT models, so model-switch cost dominates and
    cache-affinity/prefetch policies are actually exercised. Chain steps
    that would land past ``duration_s`` are dropped (every generator here
    keeps arrivals inside the window)."""
    if not models:
        raise ValueError("session_trace needs at least one model")
    if session_rate <= 0 or chain_len < 1:
        raise ValueError("session_rate must be > 0 and chain_len >= 1")
    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    t0 = 0.0
    while True:
        t0 += float(rng.exponential(1.0 / session_rate))
        if t0 >= duration_s:
            break
        start = int(rng.integers(len(models)))
        t = t0
        for step in range(chain_len):
            if t >= duration_s:
                break
            model = models[(start + step) % len(models)]
            reqs.append(_mk_request(model, t, rng, vocab, seq))
            t += float(rng.exponential(think_s))
    reqs.sort(key=lambda r: r.arrival_s)
    return reqs


def jain_fairness(values: Sequence[float]) -> float:
    """Jain's fairness index ``(sum x)^2 / (n * sum x^2)`` over
    per-tenant service levels: 1.0 = perfectly equal, 1/n = one tenant
    gets everything. All-zero (or empty) input means no tenant was
    served differently from any other — returns 1.0."""
    xs = np.asarray([float(v) for v in values], dtype=np.float64)
    if xs.size == 0:
        return 1.0
    sq = float(np.dot(xs, xs))
    if sq <= 0.0:
        return 1.0
    s = float(np.sum(xs))
    return (s * s) / (xs.size * sq)


def tenant_on_time_rates(responses,
                         tenant_of: Dict[int, str]) -> Dict[str, float]:
    """Per-tenant on-time service level over a response set: the fraction
    of each tenant's requests that were served ("ok") AND met their
    deadline (no-deadline serves count as on time). One vectorized pass
    over the response columns — same kernel for object lists and columnar
    ``ResponseTable``s (PR 10), so the trace-scale benchmarks get
    identical numbers in either mode. Requests whose ``req_id`` is absent
    from ``tenant_of`` are ignored; feed the result to
    ``jain_fairness``."""
    c = response_columns(responses)
    req_id, status = c["req_id"], c["status"]
    if not len(tenant_of) or not req_id.size:
        return {}
    _, missed = _judged_missed(c)
    on_time = (status == _STATUS_OK) & ~missed
    # tenants are few, requests are many: map each row to a tenant code
    # with one sorted-key searchsorted instead of a per-row dict lookup
    tenants = sorted(set(tenant_of.values()))
    code = {t: i for i, t in enumerate(tenants)}
    keys = np.fromiter(tenant_of.keys(), dtype=np.int64,
                       count=len(tenant_of))
    vals = np.fromiter((code[v] for v in tenant_of.values()),
                       dtype=np.int64, count=len(tenant_of))
    order = np.argsort(keys)
    keys, vals = keys[order], vals[order]
    pos = np.clip(np.searchsorted(keys, req_id), 0, keys.size - 1)
    row_code = np.where(keys[pos] == req_id, vals[pos], -1)
    tot = np.bincount(row_code[row_code >= 0], minlength=len(tenants))
    good = np.bincount(row_code[(row_code >= 0) & on_time],
                       minlength=len(tenants))
    return {t: (int(good[i]) / int(tot[i]) if tot[i] else 0.0)
            for i, t in enumerate(tenants)}
