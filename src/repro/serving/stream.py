"""Live request streams + arrival-trace generators for the online loop.

``RequestStream`` is the engine's pull interface: producers ``push``
requests (each stamped with an ``arrival_s`` on the serving clock's
timeline) and ``serve()`` polls for everything that has *arrived* by the
current clock reading. A pre-filled, closed stream replays a trace
deterministically — the benchmark/test mode; a live stream is the same
object with concurrent pushers.

``peek_upcoming`` exposes not-yet-arrived requests (known only for trace
replays). The engine uses it purely as a prefetch *hint* — to warm the
model of the next future arrival when every queue is empty — never for
scheduling decisions about arrived work.

Trace generators (``poisson_trace``, ``bursty_trace``) are seeded and
shared by tests and ``benchmarks/bursty_arrivals.py`` so both replay the
exact same workloads.
"""
from __future__ import annotations

import heapq
import itertools
import math
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.types import Request


class RequestStream:
    """Arrival-ordered request source (thread-safe heap on ``arrival_s``)."""

    def __init__(self, requests: Sequence[Request] = (),
                 closed: bool = False):
        self._lock = threading.Lock()
        # push/close signal: an event-driven consumer blocks here instead
        # of polling every poll_interval_s (ServeSession.run step_mode=
        # "event" on a real clock)
        self._cond = threading.Condition(self._lock)
        self._seq = itertools.count()     # FIFO tie-break for equal arrivals
        self._heap: List[Tuple[float, int, Request]] = []
        self._closed = False
        for r in requests:
            self.push(r)
        if closed:
            self.close()

    @staticmethod
    def from_trace(requests: Sequence[Request]) -> "RequestStream":
        """A closed, replayable stream — the deterministic benchmark mode."""
        return RequestStream(requests, closed=True)

    def push(self, req: Request):
        # a real error, not an assert: pushing to a closed stream is a
        # producer bug that must surface under `python -O` too (asserts
        # are stripped there and the request would vanish silently)
        with self._lock:
            if self._closed:
                raise RuntimeError("push on closed RequestStream")
            heapq.heappush(self._heap, (req.arrival_s, next(self._seq), req))
            self._cond.notify_all()

    def close(self):
        """Idempotent: closing an already-closed stream is a no-op (several
        producers may all signal end-of-trace)."""
        with self._lock:
            self._closed = True
            self._cond.notify_all()

    @property
    def closed(self) -> bool:
        with self._lock:
            return self._closed

    @property
    def exhausted(self) -> bool:
        """Closed and fully drained — the serve loop's stop condition."""
        with self._lock:
            return self._closed and not self._heap

    def poll(self, now: float) -> List[Request]:
        """Pop every request that has arrived by ``now`` (arrival order)."""
        out: List[Request] = []
        with self._lock:
            while self._heap and self._heap[0][0] <= now:
                out.append(heapq.heappop(self._heap)[2])
        return out

    def next_arrival(self) -> Optional[float]:
        """Earliest pending arrival time, or None if nothing is queued."""
        with self._lock:
            return self._heap[0][0] if self._heap else None

    def pending_count(self) -> int:
        """Requests pushed but not yet polled (the router's inbox-depth
        component of a replica's load)."""
        with self._lock:
            return len(self._heap)

    def peek_next(self) -> Optional[Request]:
        """The earliest pending request WITHOUT popping it, or None — the
        heap top, O(1). The engine's speculative-prefetch fallback checks
        this first and only falls back to ``peek_upcoming`` (O(n) over a
        trace-scale heap) when the top can't be warmed."""
        with self._lock:
            return self._heap[0][2] if self._heap else None

    def peek_upcoming(self, n: int = 8) -> List[Request]:
        """Up to ``n`` earliest pending requests WITHOUT popping them."""
        with self._lock:
            return [r for _, _, r in heapq.nsmallest(n, self._heap)]

    def wait_for_push(self, timeout: Optional[float] = None, *,
                      before_s: float = math.inf) -> bool:
        """Block (REAL time) until the stream closes or holds a pending
        arrival stamped earlier than ``before_s``, or ``timeout`` seconds
        pass. Returns True when woken by stream state, False on timeout.

        This is the event-driven idle wait for live (open) streams on a
        real clock: instead of spinning ``poll_interval_s`` ticks, the
        serve loop parks here and a producer's ``push``/``close`` wakes
        it immediately — one step per event. The check runs under the
        stream lock, so a push that landed between the caller's last poll
        and this wait is seen on entry, never missed."""
        deadline = (time.monotonic() + timeout) if timeout is not None \
            else None
        with self._cond:
            while True:
                if self._closed or (self._heap
                                    and self._heap[0][0] < before_s):
                    return True
                if deadline is None:
                    self._cond.wait()
                else:
                    left = deadline - time.monotonic()
                    if left <= 0 or not self._cond.wait(left):
                        return False


# ---------------------------------------------------------------------------
# trace generators (seeded — tests and benchmarks replay identical traffic)
# ---------------------------------------------------------------------------

def _mk_request(model: str, t: float, rng: np.random.Generator,
                vocab: int, seq: int, batch: int = 1) -> Request:
    toks = rng.integers(0, vocab, (batch, seq), dtype=np.int32)
    return Request(model=model, tokens=toks, arrival_s=t)


def poisson_trace(rates: Dict[str, float], duration_s: float, *,
                  vocab: int, seq: int, seed: int = 0) -> List[Request]:
    """Independent Poisson arrivals per model (``rates`` in req/s)."""
    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    for model, rate in rates.items():
        # non-positive rates mean "no arrivals" (launch/serve.py --mix
        # drops zero-weight models the same way): rate == 0 would divide
        # by zero below, and rate < 0 would step time backwards forever
        if rate <= 0:
            continue
        t = 0.0
        while True:
            t += float(rng.exponential(1.0 / rate))
            if t >= duration_s:
                break
            reqs.append(_mk_request(model, t, rng, vocab, seq))
    reqs.sort(key=lambda r: r.arrival_s)
    return reqs


def stamp_req_ids(trace: Sequence[Request], *, start: int = 0
                  ) -> List[Request]:
    """Stamp a unique per-trace request index onto ``req_id`` (NEW
    ``Request`` objects; tokens shared, not copied). The engine echoes
    ``req_id`` on every ``Response``, so metrics and reference outputs
    can be keyed by it — ``(model, arrival_s)`` keys silently collapse
    two same-model requests with identical arrivals (the PR-8 bugfix).
    Requests that already carry a ``req_id`` keep it; everything else
    gets ``start + position``."""
    from dataclasses import replace
    return [r if r.req_id is not None else replace(r, req_id=start + i)
            for i, r in enumerate(trace)]


def assign_priorities(trace: Sequence[Request],
                      mix: Dict[float, float], *, seed: int = 0
                      ) -> List[Request]:
    """Stamp seeded random priorities onto a trace: ``mix`` maps priority
    weight -> probability (normalized). Returns NEW ``Request`` objects
    (same tokens / arrivals / deadlines — tokens shared, not copied) so
    the unstamped trace can be replayed as the uniform-priority baseline
    while per-class metrics are still computed against this assignment.
    Key the assignment by unique ``req_id`` (``stamp_req_ids``) — NOT by
    ``(model, arrival_s)``, which overwrites silently when two same-model
    requests share an arrival time."""
    from dataclasses import replace
    rng = np.random.default_rng(seed)
    levels = sorted(mix)
    probs = np.array([mix[p] for p in levels], dtype=float)
    total = probs.sum()
    if total <= 0:
        raise ValueError(f"priority mix has no mass: {mix}")
    draws = rng.choice(len(levels), size=len(trace), p=probs / total)
    return [replace(r, priority=float(levels[d]))
            for r, d in zip(trace, draws)]


def bursty_trace(base_rates: Dict[str, float], duration_s: float, *,
                 burst_model: str, burst_at_s: float, burst_n: int,
                 burst_span_s: float, vocab: int, seq: int,
                 seed: int = 0) -> List[Request]:
    """Poisson background traffic plus one dense burst of a single model —
    the paper-motivated pattern that invalidates static interleave order."""
    reqs = poisson_trace(base_rates, duration_s, vocab=vocab, seq=seq,
                         seed=seed)
    rng = np.random.default_rng(seed + 1)
    step = burst_span_s / max(burst_n, 1)
    for i in range(burst_n):
        t = burst_at_s + i * step
        # a burst whose span crosses the end of the trace would stamp
        # arrivals past duration_s — outside the window every consumer
        # (and the Poisson background above) guarantees; drop them
        if t >= duration_s:
            break
        reqs.append(_mk_request(burst_model, t, rng, vocab, seq))
    reqs.sort(key=lambda r: r.arrival_s)
    return reqs
