"""Serving-layer datatypes shared by the engine, batcher, and streams.

Kept free of engine imports so ``serving/batcher.py`` and
``serving/stream.py`` can build on ``Request`` without a cycle through
``serving/engine.py`` (which imports both).

SLO machinery (PR 3): a ``Request`` may carry an explicit ``deadline_s``
on the serving clock's timeline; when it does not, the engine derives one
from the per-model ``SLOConfig`` (``arrival + slo``). A ``Response``
reports whether its request was served (``status="ok"``) or refused by
the admission controller (``status="rejected"``) — shedding infeasible
work is an explicit, observable outcome instead of silent tail-latency
inflation. ``deadline_miss_rate`` / ``rejection_rate`` are the shared
metric reductions the benchmarks and scenario tests both use, so A/B
numbers always mean the same thing.

Priorities (PR 5): a ``Request`` also carries a ``priority`` weight
(default 1.0). Under ``scheduler="slo"`` the EDF key becomes
priority-weighted (weighted slack; see ``engine.serve``), admission and
shedding prefer dropping low-priority work first, and ``priority == 0``
marks best-effort traffic that never displaces deadline work.
``priority_miss_rate`` (priority-weighted misses) and
``per_priority_stats`` (per-weight latency percentiles) are the matching
metric reductions.
"""
from __future__ import annotations

import math
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, Iterable, Iterator, Optional

import numpy as np


class RingLog:
    """Bounded decision log: keeps the most recent ``cap`` entries plus an
    exact lifetime ``total`` count, so observability memory is O(cap) in
    trace length while streaming aggregates stay exact (PR 8).

    Every engine/router decision log (``batch_log``, ``admission_log``,
    ``kv_log``, ``route_log``, ...) is one of these. It quacks like the
    list the logs used to be — iteration in append order, ``len`` of the
    RETAINED entries, integer/slice indexing, equality against plain
    lists — so scenario tests that replay short traces (< cap events)
    see bit-identical contents. At trace scale the tail is truncated;
    anything that must stay exact reads ``total`` or a dedicated counter
    (``ServingEngine.slo_report`` does), never ``len``."""

    __slots__ = ("_buf", "total")

    def __init__(self, cap: int = 10000, items: Iterable = ()):
        self._buf: deque = deque(items, maxlen=int(cap))
        self.total: int = len(self._buf)

    @property
    def cap(self) -> int:
        return self._buf.maxlen

    def append(self, item):
        self._buf.append(item)
        self.total += 1

    def clear(self):
        """Drop retained entries AND reset ``total`` — the semantics of
        ``list.clear`` on the old unbounded logs (tests clear a log and
        recompute aggregates from what accumulates afterwards)."""
        self._buf.clear()
        self.total = 0

    def __len__(self) -> int:
        return len(self._buf)

    def __bool__(self) -> bool:
        return bool(self._buf)

    def __iter__(self) -> Iterator:
        return iter(self._buf)

    def __getitem__(self, idx):
        if isinstance(idx, slice):
            return list(self._buf)[idx]
        return self._buf[idx]

    def __eq__(self, other) -> bool:
        if isinstance(other, RingLog):
            return list(self._buf) == list(other._buf)
        if isinstance(other, (list, tuple, deque)):
            return list(self._buf) == list(other)
        return NotImplemented

    def __repr__(self) -> str:
        return (f"RingLog(cap={self._buf.maxlen}, total={self.total}, "
                f"retained={list(self._buf)!r})")


@dataclass
class Request:
    model: str
    tokens: np.ndarray
    arrival_s: float = field(default_factory=time.perf_counter)
    # absolute completion deadline on the serving clock (None = derive from
    # the engine's SLOConfig, or "no deadline" when no SLO is configured)
    deadline_s: Optional[float] = None
    # scheduling weight: 1.0 = the PR-3 plain-EDF behaviour, > 1 shrinks the
    # request's effective slack (runs/admits earlier), 0 = best-effort
    # (served only when no deadline work competes, shed first)
    priority: float = 1.0
    # caller-assigned correlation id, echoed on the Response. The engine
    # never reads it; the fleet Router uses it to match terminal responses
    # to tracked requests across retries (a retry is a NEW Request object
    # with the same req_id)
    req_id: Optional[int] = None
    # planned decode length (generated tokens): with a unified budget pool
    # (engine kv=KVSpec(...)) the engine charges this sequence's paged KV
    # growth — prompt prefill plus decode_tokens, prorated per executed
    # segment — against the shared budget. 0 = prefill-only accounting.
    decode_tokens: int = 0

    def __post_init__(self):
        if self.priority < 0:
            raise ValueError(f"priority must be >= 0, got {self.priority}")
        if self.decode_tokens < 0:
            raise ValueError(f"decode_tokens must be >= 0, "
                             f"got {self.decode_tokens}")


@dataclass
class Response:
    model: str
    latency_s: float
    init_s: float
    exec_s: float
    peak_bytes: int
    avg_bytes: float = 0.0
    cache_hits: int = 0
    cache_misses: int = 0
    cache_hit_rate: float = 0.0
    result: object = None
    # online-loop fields (serve()): arrival-to-completion accounting and the
    # coalesced batch the request rode in. run_all() leaves them at defaults.
    arrival_s: float = 0.0
    queue_s: float = 0.0
    batch_size: int = 1
    # SLO fields: "ok" = served; "rejected" = the admission controller
    # refused the request (result is None, latency_s is time-to-decision)
    status: str = "ok"
    deadline_s: Optional[float] = None
    priority: float = 1.0
    # echo of Request.req_id (None when the caller didn't assign one)
    req_id: Optional[int] = None
    # KV bytes this request's sequence held in the unified pool at
    # completion (0 under weights-only serving)
    kv_bytes: int = 0
    # cost-model observability (serve()): what the scheduler's cost model
    # priced this request's batch at when the batch first started, and
    # what the serving clock actually charged for the whole execution.
    # Shared across a batch's members; 0.0 for rejected / run_all paths.
    predicted_s: float = 0.0
    charged_s: float = 0.0

    @property
    def finish_s(self) -> float:
        """Completion time on the serving clock (arrival + latency)."""
        return self.arrival_s + self.latency_s

    @property
    def deadline_met(self) -> Optional[bool]:
        """True/False against the deadline; None when there was no deadline
        or the request was never served (rejected)."""
        if self.deadline_s is None or not math.isfinite(self.deadline_s) \
                or self.status != "ok":
            return None
        return self.finish_s <= self.deadline_s + 1e-9


@dataclass
class SLOConfig:
    """Per-model latency SLOs: a request's default deadline is
    ``arrival_s + slo_for(model)``. ``per_model`` overrides the default
    for individual models (e.g. an interactive ASR model with a tighter
    bound than a background summarizer)."""
    default_slo_s: float = 0.25
    per_model: Dict[str, float] = field(default_factory=dict)

    def slo_for(self, model: str) -> float:
        return self.per_model.get(model, self.default_slo_s)

    def deadline_for(self, req: Request) -> float:
        return req.arrival_s + self.slo_for(req.model)


# ---------------------------------------------------------------------------
# shared SLO metric reductions (benchmarks + scenario tests)
#
# PR 10: every reducer runs ONE vectorized numpy kernel over raw column
# arrays. A columnar ResponseTable hands its arrays over zero-copy
# (``reducer_columns()``); an iterable of Response objects is extracted
# into identical arrays first — so object and columnar modes agree
# bit-for-bit by construction (same dtypes, same element order, same
# numpy reduction).
# ---------------------------------------------------------------------------

_STATUS_OK, _STATUS_REJECTED, _STATUS_FAILED = 0, 1, 2
_STATUS_TO_CODE = {"ok": _STATUS_OK, "rejected": _STATUS_REJECTED,
                   "failed": _STATUS_FAILED}


def response_columns(responses) -> dict:
    """Reducer-ready column arrays from either a ``ResponseTable``
    (zero-copy via ``reducer_columns``) or an iterable of ``Response``
    objects (extracted, preserving order). Keys: status (int8 codes),
    arrival_s/latency_s/deadline_s (NaN = no deadline)/priority/
    predicted_s/charged_s (float64), req_id (int64, -1 = unassigned),
    model_id (int32) + vocab."""
    rc = getattr(responses, "reducer_columns", None)
    if rc is not None:
        return rc()
    rs = responses if isinstance(responses, (list, tuple)) \
        else list(responses)
    n = len(rs)
    vocab: list = []
    ids: Dict[str, int] = {}
    model_id = np.empty(n, dtype=np.int32)
    for i, r in enumerate(rs):
        mid = ids.get(r.model)
        if mid is None:
            mid = ids[r.model] = len(vocab)
            vocab.append(r.model)
        model_id[i] = mid
    return {
        "status": np.fromiter(
            (_STATUS_TO_CODE.get(r.status, _STATUS_FAILED) for r in rs),
            dtype=np.int8, count=n),
        "arrival_s": np.fromiter((r.arrival_s for r in rs),
                                 dtype=np.float64, count=n),
        "latency_s": np.fromiter((r.latency_s for r in rs),
                                 dtype=np.float64, count=n),
        "deadline_s": np.fromiter(
            (np.nan if r.deadline_s is None else r.deadline_s
             for r in rs), dtype=np.float64, count=n),
        "priority": np.fromiter((r.priority for r in rs),
                                dtype=np.float64, count=n),
        "predicted_s": np.fromiter((r.predicted_s for r in rs),
                                   dtype=np.float64, count=n),
        "charged_s": np.fromiter((r.charged_s for r in rs),
                                 dtype=np.float64, count=n),
        "req_id": np.fromiter(
            (-1 if r.req_id is None else r.req_id for r in rs),
            dtype=np.int64, count=n),
        "model_id": model_id,
        "vocab": vocab,
    }


def _judged_missed(c: dict):
    """(judged, missed) masks: judged = served with a finite deadline;
    missed = judged and finished past deadline + 1e-9 (the Response.
    deadline_met tolerance)."""
    judged = (c["status"] == _STATUS_OK) & np.isfinite(c["deadline_s"])
    finish = c["arrival_s"] + c["latency_s"]
    missed = judged & ~(finish <= c["deadline_s"] + 1e-9)
    return judged, missed


def status_counts(responses) -> Dict[str, int]:
    """Exact {status: count} over responses (either storage mode)."""
    status = response_columns(responses)["status"]
    return {"ok": int(np.count_nonzero(status == _STATUS_OK)),
            "rejected": int(np.count_nonzero(status == _STATUS_REJECTED)),
            "failed": int(np.count_nonzero(status == _STATUS_FAILED))}


def deadline_miss_rate(responses) -> float:
    """Fraction of SERVED deadlined requests that finished late. Rejected
    requests are not misses — rejection is the explicit alternative the
    admission controller offers — and deadline-less requests can't miss."""
    judged, missed = _judged_missed(response_columns(responses))
    n = int(np.count_nonzero(judged))
    if n == 0:
        return 0.0
    return int(np.count_nonzero(missed)) / n


def rejection_rate(responses) -> float:
    """Fraction of all responses the admission controller refused."""
    status = response_columns(responses)["status"]
    n = status.size
    if n == 0:
        return 0.0
    return int(np.count_nonzero(status == _STATUS_REJECTED)) / n


def priority_miss_rate(responses) -> float:
    """Priority-WEIGHTED deadline miss rate: each judged response counts
    with its priority, so a priority-2 miss hurts twice as much as a
    priority-1 miss and best-effort (priority-0) work never moves the
    number — the scalar the weighted-EDF scheduler is graded on."""
    c = response_columns(responses)
    judged, missed = _judged_missed(c)
    total = float(np.sum(c["priority"][judged]))
    if total <= 0:
        return 0.0
    return float(np.sum(c["priority"][missed])) / total


def prediction_error(responses) -> Dict[str, dict]:
    """Per-model realized cost-model error over SERVED responses: how far
    the scheduler's priced batch latency (``Response.predicted_s``) landed
    from what the clock actually charged (``Response.charged_s``).
    Aggregated per response, so larger batches weigh by their member
    count — the admission/urgency decisions were made once per member.
    Responses without stamps (run_all, rejected, pre-PR traces) are
    skipped."""
    c = response_columns(responses)
    sampled = (c["status"] == _STATUS_OK) & (c["charged_s"] > 0.0)
    vocab = c["vocab"]
    out: Dict[str, dict] = {}
    for mid in sorted(np.unique(c["model_id"][sampled]).tolist(),
                      key=lambda i: vocab[i]):
        m = sampled & (c["model_id"] == mid)
        charged = c["charged_s"][m]
        abs_err = np.abs(c["predicted_s"][m] - charged)
        rel_err = abs_err / np.maximum(charged, 1e-12)
        out[vocab[mid]] = {
            "samples": int(np.count_nonzero(m)),
            "mae_s": float(np.mean(abs_err)),
            "rel_err": float(np.mean(rel_err)),
        }
    return out


def per_priority_stats(responses) -> Dict[float, "PriorityStats"]:
    """Per-priority-level breakdown: request counts, miss/rejection rates,
    and served-latency percentiles — the engine report's view of how each
    traffic class fared (high priority should miss less under overload,
    low priority should still be served: the aging/starvation check).
    Returns typed ``PriorityStats`` (PR 10) keyed by priority weight,
    ascending."""
    from repro.serving.reports import PriorityStats
    c = response_columns(responses)
    judged, missed = _judged_missed(c)
    served_mask = c["status"] == _STATUS_OK
    rejected_mask = c["status"] == _STATUS_REJECTED
    out: Dict[float, PriorityStats] = {}
    for p in np.unique(c["priority"]).tolist():
        m = c["priority"] == p
        n = int(np.count_nonzero(m))
        served = int(np.count_nonzero(m & served_mask))
        nj = int(np.count_nonzero(m & judged))
        lats = c["latency_s"][m & served_mask]
        out[float(p)] = PriorityStats(
            requests=n,
            served=served,
            rejected=int(np.count_nonzero(m & rejected_mask)),
            miss_rate=(int(np.count_nonzero(m & missed)) / nj
                       if nj else 0.0),
            rejection_rate=(int(np.count_nonzero(m & rejected_mask)) / n
                            if n else 0.0),
            p50_s=float(np.percentile(lats, 50)) if served
            else float("nan"),
            p99_s=float(np.percentile(lats, 99)) if served
            else float("nan"),
        )
    return out
