"""Serving-layer datatypes shared by the engine, batcher, and streams.

Kept free of engine imports so ``serving/batcher.py`` and
``serving/stream.py`` can build on ``Request`` without a cycle through
``serving/engine.py`` (which imports both).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np


@dataclass
class Request:
    model: str
    tokens: np.ndarray
    arrival_s: float = field(default_factory=time.perf_counter)


@dataclass
class Response:
    model: str
    latency_s: float
    init_s: float
    exec_s: float
    peak_bytes: int
    avg_bytes: float = 0.0
    cache_hits: int = 0
    cache_misses: int = 0
    cache_hit_rate: float = 0.0
    result: object = None
    # online-loop fields (serve()): arrival-to-completion accounting and the
    # coalesced batch the request rode in. run_all() leaves them at defaults.
    arrival_s: float = 0.0
    queue_s: float = 0.0
    batch_size: int = 1
