"""Request batcher: groups same-model FIFO requests into padded batches up
to `max_batch`/`max_wait_s` — standard serving-front logic, kept separate
from the engine so the FIFO semantics of the paper's evaluation stay pure
(batch size 1) unless explicitly enabled.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List

import numpy as np

from repro.serving.engine import Request


@dataclass
class BatcherConfig:
    max_batch: int = 8
    max_wait_s: float = 0.005
    pad_id: int = 0


def batch_requests(reqs: List[Request], cfg: BatcherConfig) -> List[Request]:
    """Coalesce consecutive same-model requests (FIFO order preserved)."""
    out: List[Request] = []
    i = 0
    while i < len(reqs):
        j = i + 1
        group = [reqs[i]]
        while (j < len(reqs) and reqs[j].model == reqs[i].model
               and len(group) < cfg.max_batch
               and reqs[j].arrival_s - reqs[i].arrival_s <= cfg.max_wait_s):
            group.append(reqs[j])
            j += 1
        if len(group) == 1:
            out.append(reqs[i])
        else:
            s = max(r.tokens.shape[1] for r in group)
            toks = np.full((sum(r.tokens.shape[0] for r in group), s),
                           cfg.pad_id, np.int32)
            row = 0
            for r in group:
                b, sl = r.tokens.shape
                toks[row: row + b, :sl] = r.tokens
                row += b
            out.append(Request(model=group[0].model, tokens=toks,
                               arrival_s=group[0].arrival_s))
        i = j
    return out
