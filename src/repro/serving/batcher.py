"""Request batcher: groups same-model FIFO requests into padded batches up
to `max_batch`/`max_wait_s` — the serving front the online loop
(``ServingEngine.serve``) coalesces traffic through. Kept separate from
the engine so the FIFO semantics of the paper's evaluation stay pure
(batch size 1) unless explicitly enabled.

``make_batch`` pads a same-model group to the max sequence length and
records each member's row span + true length; ``split_batch_result``
inverts it, slicing the batched output back to per-request results.
Causal attention + per-position norms make the padded prefix rows
bit-for-bit equal to a solo run, so de-batched streamed outputs still
compare exactly against per-request preload references.

Deadline-aware capping (PR 5): ``make_batch`` optionally takes the
scheduler's cost view (``now`` / ``estimate(batch_size)`` /
``restream_cost_s`` / ``deadline_of``) and then admits members greedily
only while the grown batch still makes the tightest admitted deadline —
joining can never blow the head's deadline (the real-time regression
Demand Layering warns against when loading/exec pipelines run under a
deadline). Members the cap excludes come back in ``Batch.deferred`` in
FIFO order so the engine can requeue them at the head of the line. With
slack deadlines the cap never binds and the batch is bit-for-bit
identical to the uncapped one.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.serving.types import Request


def _deadline_or_inf(r: Request) -> float:
    return r.deadline_s if r.deadline_s is not None else math.inf


@dataclass
class BatcherConfig:
    max_batch: int = 8
    max_wait_s: float = 0.005
    pad_id: int = 0


@dataclass
class Batch:
    """A coalesced same-model group + the bookkeeping to un-coalesce it."""
    model: str
    tokens: np.ndarray
    requests: List[Request] = field(default_factory=list)
    row_spans: List[Tuple[int, int]] = field(default_factory=list)
    seq_lens: List[int] = field(default_factory=list)
    # members the deadline-aware feasibility cap excluded, FIFO order —
    # the engine requeues these at the head of the model's queue
    deferred: List[Request] = field(default_factory=list)

    @property
    def arrival_s(self) -> float:
        return self.requests[0].arrival_s if self.requests else 0.0

    @property
    def size(self) -> int:
        return len(self.requests)

    @property
    def deadline_s(self) -> float:
        """The batch's effective deadline: the tightest member deadline
        (the whole fused execution must land by then), +inf when no member
        carries one — what the engine's preemption check compares against."""
        ds = [r.deadline_s for r in self.requests if r.deadline_s is not None]
        return min(ds) if ds else math.inf

    @property
    def priority(self) -> float:
        """The batch's scheduling weight: the highest member priority (a
        high-priority rider must not lose its weight by being coalesced
        with background work)."""
        return max((r.priority for r in self.requests), default=1.0)


def feasible_prefix(group: List[Request], *, now: float,
                    estimate: Callable[[int], float],
                    restream_cost_s: float = 0.0,
                    deadline_of: Optional[Callable[[Request], float]] = None,
                    ) -> int:
    """Largest FIFO prefix of ``group`` that one fused execution can serve
    without blowing any admitted member's deadline: members are admitted
    greedily while ``now + estimate(k) + restream_cost_s`` stays within
    the tightest deadline among the first ``k`` members (the head's
    deadline when the head is tightest — a later member with an even
    tighter deadline tightens the bound further, never loosens it). The
    head itself is always admitted: its own feasibility is the admission
    controller's job, not the batcher's."""
    dl = deadline_of or _deadline_or_inf
    eff = dl(group[0])
    k = 1
    while k < len(group):
        cand_eff = min(eff, dl(group[k]))
        if now + estimate(k + 1) + restream_cost_s > cand_eff + 1e-9:
            break
        eff = cand_eff
        k += 1
    return k


def make_batch(group: List[Request], cfg: BatcherConfig, *,
               now: Optional[float] = None,
               estimate: Optional[Callable[[int], float]] = None,
               restream_cost_s: float = 0.0,
               deadline_of: Optional[Callable[[Request], float]] = None,
               ) -> Batch:
    """Pad a same-model FIFO group to one (sum_b, max_s) token batch.

    With ``estimate`` (and ``now``) the deadline-aware feasibility cap is
    applied first: only the ``feasible_prefix`` of the group is batched
    and the excluded tail lands in ``Batch.deferred`` (FIFO order) for the
    caller to requeue. Without them every member is admitted — the PR-2
    behaviour, bit-for-bit."""
    if not group:
        raise ValueError("make_batch: empty request group")
    if len({r.model for r in group}) != 1:
        raise ValueError("make_batch: cross-model group "
                         f"{sorted({r.model for r in group})}")
    deferred: List[Request] = []
    if estimate is not None:
        if now is None:
            raise ValueError("make_batch: feasibility cap needs `now`")
        k = feasible_prefix(group, now=now, estimate=estimate,
                            restream_cost_s=restream_cost_s,
                            deadline_of=deadline_of)
        group, deferred = group[:k], group[k:]
    s = max(r.tokens.shape[1] for r in group)
    toks = np.full((sum(r.tokens.shape[0] for r in group), s),
                   cfg.pad_id, np.int32)
    batch = Batch(model=group[0].model, tokens=toks, requests=list(group),
                  deferred=deferred)
    row = 0
    for r in group:
        b, sl = r.tokens.shape
        toks[row: row + b, :sl] = r.tokens
        batch.row_spans.append((row, row + b))
        batch.seq_lens.append(sl)
        row += b
    return batch


def split_batch_result(batch: Batch, result) -> List[np.ndarray]:
    """De-batch a (batch, seq, ...) output back to per-request slices,
    dropping each member's padded tail — the round-trip inverse of
    ``make_batch``."""
    arr = np.asarray(result)
    if arr.shape[0] != batch.tokens.shape[0]:
        raise ValueError(
            f"split_batch_result: result has {arr.shape[0]} rows, batch "
            f"was made from {batch.tokens.shape[0]} — not this batch's "
            "output")
    out = []
    for (lo, hi), sl in zip(batch.row_spans, batch.seq_lens):
        out.append(arr[lo:hi, :sl])
    return out


def can_join(head: Request, candidate: Request, group_size: int,
             cfg: BatcherConfig) -> bool:
    """THE grouping rule, in one place (the engine's online loop and the
    legacy list batcher both delegate here): same model as the group head,
    within ``max_wait_s`` of the head's arrival, group below ``max_batch``."""
    return (candidate.model == head.model
            and group_size < cfg.max_batch
            and candidate.arrival_s - head.arrival_s <= cfg.max_wait_s)


def group_requests(reqs: List[Request], cfg: BatcherConfig) -> List[List[Request]]:
    """Split a FIFO request list into coalescible groups (``can_join``
    applied to consecutive requests). Cross-model requests never share a
    group and per-model FIFO order is preserved."""
    groups: List[List[Request]] = []
    i = 0
    while i < len(reqs):
        j = i + 1
        group = [reqs[i]]
        while j < len(reqs) and can_join(reqs[i], reqs[j], len(group), cfg):
            group.append(reqs[j])
            j += 1
        groups.append(group)
        i = j
    return groups


def batch_requests(reqs: List[Request], cfg: BatcherConfig) -> List[Request]:
    """Coalesce consecutive same-model requests (FIFO order preserved) into
    padded ``Request``s — the legacy list-in/list-out front used when the
    caller does not need de-batching."""
    out: List[Request] = []
    for group in group_requests(reqs, cfg):
        if len(group) == 1:
            out.append(group[0])
        else:
            b = make_batch(group, cfg)
            out.append(Request(model=b.model, tokens=b.tokens,
                               arrival_s=b.arrival_s))
    return out
