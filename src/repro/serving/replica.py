"""One fleet replica: a ``ServingEngine`` + its own budgeted pool, clock,
and inbox, behind the front-end ``Router`` (serving/router.py).

A ``Replica`` wraps one engine in the steppable ``ServeSession`` form:
the Router pushes routed requests into the replica's ``RequestStream``
inbox and advances the replica's ``ReplicaClock`` to the session's
``next_time()`` before each step — N replicas interleave as one
deterministic discrete-event simulation on a shared virtual timeline (no
threads, no real sleeps). Each replica is notionally pinned to a device:
``jax.devices()[rid % n_devices]`` — on a one-device host every replica
shares it (the simulated-fleet mode the tests and benchmarks run in);
on a multi-accelerator host the modulo spreads them.

``FaultPlan`` is the injectable failure schedule, stamped in virtual
seconds on the ROUTER's watermark clock:

  * ``kill``  — the replica stops stepping permanently; requests already
    routed to it strand until the Router's per-request timeout fires.
  * ``wedge`` — same, but a later ``recover`` event revives it (its clock
    is advanced to the recovery time: the backlog it slept through is
    served late, exactly like a process unfrozen by the scheduler).
  * ``slow``  — every subsequent execution charge is multiplied by
    ``factor`` (thermal throttling / noisy neighbour). The Router's
    ``StragglerDetector`` sees the inflated per-batch latencies.
  * ``recover`` — clears wedge/slow.

The Router never reads fault state when routing — failures are only
observable the way a real front-end sees them: timeouts, stragglers, and
the circuit breaker those feed.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax

from repro.serving.clock import SimClock
from repro.serving.engine import ServeSession, ServingEngine
from repro.serving.reports import ReplicaHealth
from repro.serving.stream import RequestStream
from repro.serving.types import RingLog

FAULT_KINDS = ("kill", "wedge", "slow", "recover")


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: at virtual time ``t_s`` (router watermark),
    do ``kind`` to replica ``rid``. ``factor`` only applies to "slow"."""
    t_s: float
    rid: int
    kind: str
    factor: float = 4.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.kind == "slow" and self.factor <= 1.0:
            raise ValueError(f"slow factor must be > 1, got {self.factor}")


@dataclass
class FaultPlan:
    """An ordered schedule of ``FaultEvent``s. Builder methods chain:

        FaultPlan().kill(0.5, rid=1)
        FaultPlan().slow(0.2, rid=0, factor=8.0).recover(0.8, rid=0)
    """
    events: List[FaultEvent] = field(default_factory=list)

    def add(self, ev: FaultEvent) -> "FaultPlan":
        self.events.append(ev)
        return self

    def kill(self, t_s: float, rid: int) -> "FaultPlan":
        return self.add(FaultEvent(t_s, rid, "kill"))

    def wedge(self, t_s: float, rid: int) -> "FaultPlan":
        return self.add(FaultEvent(t_s, rid, "wedge"))

    def slow(self, t_s: float, rid: int, factor: float = 4.0) -> "FaultPlan":
        return self.add(FaultEvent(t_s, rid, "slow", factor))

    def recover(self, t_s: float, rid: int) -> "FaultPlan":
        return self.add(FaultEvent(t_s, rid, "recover"))

    def sorted_events(self) -> List[FaultEvent]:
        return sorted(self.events, key=lambda e: (e.t_s, e.rid))


class ReplicaClock(SimClock):
    """Per-replica virtual clock: a ``SimClock`` whose execution charges
    can be inflated by a fault-injected ``slow_factor`` (>= 1). Idle
    advances are never inflated — a throttled device computes slowly but
    waits at normal speed."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self.slow_factor = 1.0

    def tick(self, real_dt: float, model: str = "", frac: float = 1.0,
             batch_size: int = 1) -> float:
        dt = super().tick(real_dt, model, frac=frac, batch_size=batch_size)
        extra = dt * (self.slow_factor - 1.0)
        if extra > 0:
            self._t += extra
            dt += extra
        return dt


class Replica:
    """One engine + clock + inbox, stepped by the Router.

    ``engine_kw`` goes straight to ``ServingEngine`` (each replica gets
    its OWN ``budget_bytes`` pool — the fleet is a partitioned cache, not
    a shared one). Register models, then ``start()`` to open the serve
    session; the Router owns pushing/stepping from there.
    """

    def __init__(self, rid: int, *, clock: Optional[ReplicaClock] = None,
                 engine: Optional[ServingEngine] = None, **engine_kw):
        self.rid = rid
        self.name = f"r{rid}"
        self.engine = engine if engine is not None \
            else ServingEngine(**engine_kw)
        self.clock = clock or ReplicaClock()
        self.inbox = RequestStream()
        self.session: Optional[ServeSession] = None
        # fault state (set by the Router's fault dispatcher, never read
        # by routing decisions)
        self.dead = False
        self.wedged = False
        devs = jax.devices()
        self.device = devs[rid % len(devs)]
        # (finish_t, model, charged_s) per completed batch — the
        # straggler detector's per-replica latency feed. Ring-buffered
        # (engine log_cap): the detector only reads the latest entries,
        # and `.total` keeps the lifetime batch count exact at trace scale
        self.batch_feed = RingLog(self.engine.log_cap)

    def register(self, name: str, model) -> "Replica":
        self.engine.register(name, model)
        return self

    def start(self, **serve_kw):
        self.session = self.engine.serve_session(self.inbox,
                                                 clock=self.clock,
                                                 **serve_kw)
        return self

    # -- health / state the Router may observe -----------------------------
    @property
    def responsive(self) -> bool:
        return not (self.dead or self.wedged)

    def load(self) -> int:
        """Outstanding depth: inbox + admitted queues + suspended batch."""
        n = self.inbox.pending_count()
        if self.session is not None:
            n += self.session.queued()
        return n

    def hot_bytes(self, model: str) -> int:
        """Pool-resident bytes of ``model`` (0 without a shared pool)."""
        cache = self.engine.cache
        return cache.model_bytes(model) if cache is not None else 0

    def free_budget(self) -> int:
        cache = self.engine.cache
        return cache.free_bytes() if cache is not None else 0

    def restream_bytes(self) -> int:
        """Cold-chunk bytes streamed from storage into this replica's pool
        so far — the fleet A/B's affinity metric."""
        cache = self.engine.cache
        return cache.stats.inserted_bytes if cache is not None else 0

    # -- stepping (Router only) --------------------------------------------
    def next_time(self) -> float:
        """When stepping this replica can next make progress on the shared
        timeline (+inf while dead/wedged: a faulted replica holds time
        still until recovery — or forever)."""
        if self.session is None or not self.responsive:
            return math.inf
        return self.session.next_time()

    def step(self) -> Tuple[str, object]:
        """Advance the replica clock to its next progress point and step
        the session once. Completed batches land in ``batch_feed``."""
        nt = self.next_time()
        now = self.clock.now()
        if math.isfinite(nt) and nt > now:
            self.clock.advance(nt - now)
        kind, payload = self.session.step()
        if kind == "batch":
            model, charged = payload
            self.batch_feed.append((self.clock.now(), model, charged))
        return kind, payload

    def health(self) -> ReplicaHealth:
        """Live observable state as a typed report (PR 10) — the same
        ``ReplicaHealth`` shape the Router embeds per-replica in its
        ``FleetReport`` (there with breaker fields filled instead)."""
        return ReplicaHealth(
            rid=self.rid, dead=self.dead, wedged=self.wedged,
            slow_factor=self.clock.slow_factor, load=self.load(),
            clock_s=self.clock.now(), batches=self.batch_feed.total,
            free_budget=self.free_budget(),
            restream_bytes=self.restream_bytes())
